//! Deterministic, dependency-free RNG for the simulation substrate.
//!
//! We use SplitMix64 for seeding and a xoshiro256++-style generator for the
//! hot path.  Cross-platform deterministic: every experiment in
//! EXPERIMENTS.md is reproducible from its seed.

/// SplitMix64 step — used for seeding and cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Fast counter-based hash: maps (seed, index) to a u64. Stateless, so
/// parallel loops can draw independent streams without shared state.
#[inline]
pub fn hash2(seed: u64, idx: u64) -> u64 {
    let mut s = seed ^ idx.wrapping_mul(0xA24BAED4963EE407);
    splitmix64(&mut s)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (never produces the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // take the top 24 bits for an unbiased float mantissa
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) f64.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        // Lemire's unbiased bounded sampling (single pass is fine here:
        // the modulo bias of (2^32 % n)/2^32 is < 2^-24 for our n <= 256,
        // far below any statistic we test — but do it right anyway).
        let mut x = self.next_u64() as u32;
        let mut m = (x as u64) * (n as u64);
        let mut lo = m as u32;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64() as u32;
                m = (x as u64) * (n as u64);
                lo = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Standard normal via Box–Muller (cached second value not kept —
    /// callers in the hot path use `normal_pair`).
    #[inline]
    pub fn normal(&mut self) -> f32 {
        self.normal_pair().0
    }

    /// Two independent standard normals from one Box–Muller transform.
    #[inline]
    pub fn normal_pair(&mut self) -> (f32, f32) {
        let u1 = (self.next_f64()).max(1e-12);
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        ((r * c) as f32, (r * s) as f32)
    }

    /// Counter-based stream: a generator that depends only on
    /// `(seed, idx)`, not on how many other streams exist or which thread
    /// draws from them.  This is the parallel-RNG discipline of the batched
    /// execution engine (DESIGN.md): sample `i` of a batch always uses
    /// `Rng::stream(batch_seed, i)`, so results are bit-identical at any
    /// thread count.
    #[inline]
    pub fn stream(seed: u64, idx: u64) -> Rng {
        Rng::new(hash2(seed, idx))
    }

    /// Split off a statistically independent child generator, advancing
    /// this one.  Use when a sub-task needs its own stream but no natural
    /// counter exists; prefer [`Rng::stream`] for indexed parallel work.
    #[inline]
    pub fn split(&mut self) -> Rng {
        let s = self.next_u64();
        Rng::new(hash2(s, 0x5EED_5717_A17E_u64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(42);
        let n = 100_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn stream_is_deterministic_and_decorrelated() {
        let mut a = Rng::stream(9, 3);
        let mut b = Rng::stream(9, 3);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // neighbouring streams and neighbouring seeds differ
        assert_ne!(Rng::stream(9, 3).next_u64(), Rng::stream(9, 4).next_u64());
        assert_ne!(Rng::stream(9, 3).next_u64(), Rng::stream(10, 3).next_u64());
        // a stream is independent of the sequential draw position
        assert_ne!(Rng::stream(9, 3).next_u64(), Rng::new(9).next_u64());
    }

    #[test]
    fn split_diverges_from_parent() {
        let mut parent = Rng::new(5);
        let mut child = parent.split();
        let mut tail = parent.clone();
        for _ in 0..32 {
            assert_ne!(child.next_u64(), tail.next_u64());
        }
        // splitting advanced the parent: two splits differ
        let mut p2 = Rng::new(5);
        let c1 = p2.split().next_u64();
        let c2 = p2.split().next_u64();
        assert_ne!(c1, c2);
    }

    #[test]
    fn hash2_decorrelates() {
        let a = hash2(1, 0);
        let b = hash2(1, 1);
        let c = hash2(2, 0);
        assert_ne!(a, b);
        assert_ne!(a, c);
    }
}
