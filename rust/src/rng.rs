//! Deterministic, dependency-free RNG for the simulation substrate.
//!
//! We use SplitMix64 for seeding and a xoshiro256++-style generator for the
//! hot path.  Cross-platform deterministic: every experiment in
//! EXPERIMENTS.md is reproducible from its seed.

/// SplitMix64 step — used for seeding and cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Fast counter-based hash: maps (seed, index) to a u64. Stateless, so
/// parallel loops can draw independent streams without shared state.
#[inline]
pub fn hash2(seed: u64, idx: u64) -> u64 {
    let mut s = seed ^ idx.wrapping_mul(0xA24BAED4963EE407);
    splitmix64(&mut s)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (never produces the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // take the top 24 bits for an unbiased float mantissa
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) f64.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        // Lemire's unbiased bounded sampling (single pass is fine here:
        // the modulo bias of (2^32 % n)/2^32 is < 2^-24 for our n <= 256,
        // far below any statistic we test — but do it right anyway).
        let mut x = self.next_u64() as u32;
        let mut m = (x as u64) * (n as u64);
        let mut lo = m as u32;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64() as u32;
                m = (x as u64) * (n as u64);
                lo = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Bulk RTN state sampler: fill `out` with uniform indices in
    /// `[0, m)`, eight per `next_u64`.
    ///
    /// Each 64-bit draw is split into eight bytes (low byte first); byte
    /// `b` maps to an index via the multiply-shift `(b * m) >> 8`.  For
    /// `m` dividing 256 (1, 2, 4, ..., 256 — including the default
    /// 4-state device) the map is exactly uniform; otherwise each
    /// index's probability deviates from `1/m` by less than `2^-8`
    /// absolute (equivalently `< m/256` relative), far below anything
    /// the device statistics resolve.  Bound pinned by
    /// `bulk_indices_bias_bound`; distribution by
    /// `bulk_indices_chi_square`.
    ///
    /// A trailing partial block consumes one full `next_u64` and drops
    /// the unused high bytes, so the stream position after a fill
    /// depends only on `out.len()` — `ceil(len / 8)` draws — never on
    /// how callers slice their buffers.
    ///
    /// This replaces the per-cell `below(m)` rejection loop in the MAC
    /// hot path: one serially-dependent RNG step now feeds eight cells,
    /// and the byte→index map is branch-free, which is what lets the
    /// tile kernel autovectorize.
    #[inline]
    pub fn fill_state_indices(&mut self, m: u32, out: &mut [u8]) {
        debug_assert!((1..=256).contains(&m), "num_states {m} out of range");
        let mut chunks = out.chunks_exact_mut(8);
        for chunk in &mut chunks {
            let x = self.next_u64();
            for (i, o) in chunk.iter_mut().enumerate() {
                let b = (x >> (8 * i)) & 0xFF;
                *o = ((b as u32 * m) >> 8) as u8;
            }
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let x = self.next_u64();
            for (i, o) in rem.iter_mut().enumerate() {
                let b = (x >> (8 * i)) & 0xFF;
                *o = ((b as u32 * m) >> 8) as u8;
            }
        }
    }

    /// Standard normal via Box–Muller (cached second value not kept —
    /// callers in the hot path use `normal_pair`).
    #[inline]
    pub fn normal(&mut self) -> f32 {
        self.normal_pair().0
    }

    /// Fill `out` with standard normals, consuming *both* Box–Muller
    /// values per transform.  Dataset-generation and weight-init loops
    /// should use this (or [`Rng::normal_pair`]) rather than calling
    /// [`Rng::normal`] per element, which discards every second value
    /// and doubles the `ln`/`sin_cos` cost.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        let mut chunks = out.chunks_exact_mut(2);
        for pair in &mut chunks {
            let (a, b) = self.normal_pair();
            pair[0] = a;
            pair[1] = b;
        }
        if let [last] = chunks.into_remainder() {
            *last = self.normal_pair().0;
        }
    }

    /// Two independent standard normals from one Box–Muller transform.
    #[inline]
    pub fn normal_pair(&mut self) -> (f32, f32) {
        let u1 = (self.next_f64()).max(1e-12);
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        ((r * c) as f32, (r * s) as f32)
    }

    /// Counter-based stream: a generator that depends only on
    /// `(seed, idx)`, not on how many other streams exist or which thread
    /// draws from them.  This is the parallel-RNG discipline of the batched
    /// execution engine (DESIGN.md): sample `i` of a batch always uses
    /// `Rng::stream(batch_seed, i)`, so results are bit-identical at any
    /// thread count.
    #[inline]
    pub fn stream(seed: u64, idx: u64) -> Rng {
        Rng::new(hash2(seed, idx))
    }

    /// Split off a statistically independent child generator, advancing
    /// this one.  Use when a sub-task needs its own stream but no natural
    /// counter exists; prefer [`Rng::stream`] for indexed parallel work.
    #[inline]
    pub fn split(&mut self) -> Rng {
        let s = self.next_u64();
        Rng::new(hash2(s, 0x5EED_5717_A17E_u64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(42);
        let n = 100_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn stream_is_deterministic_and_decorrelated() {
        let mut a = Rng::stream(9, 3);
        let mut b = Rng::stream(9, 3);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // neighbouring streams and neighbouring seeds differ
        assert_ne!(Rng::stream(9, 3).next_u64(), Rng::stream(9, 4).next_u64());
        assert_ne!(Rng::stream(9, 3).next_u64(), Rng::stream(10, 3).next_u64());
        // a stream is independent of the sequential draw position
        assert_ne!(Rng::stream(9, 3).next_u64(), Rng::new(9).next_u64());
    }

    #[test]
    fn split_diverges_from_parent() {
        let mut parent = Rng::new(5);
        let mut child = parent.split();
        let mut tail = parent.clone();
        for _ in 0..32 {
            assert_ne!(child.next_u64(), tail.next_u64());
        }
        // splitting advanced the parent: two splits differ
        let mut p2 = Rng::new(5);
        let c1 = p2.split().next_u64();
        let c2 = p2.split().next_u64();
        assert_ne!(c1, c2);
    }

    #[test]
    fn hash2_decorrelates() {
        let a = hash2(1, 0);
        let b = hash2(1, 1);
        let c = hash2(2, 0);
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn golden_stream_vectors() {
        // Absolute pins for the raw generator and the bulk sampler,
        // cross-computed with an independent (Python) implementation of
        // splitmix64 + xoshiro256++ + the multiply-shift map.  These
        // freeze the redefined PR-6 noise stream: any change to seeding,
        // the generator, byte order, or the index map is a breaking
        // noise-stream change and must be called out as such.
        let mut r = Rng::new(42);
        assert_eq!(r.next_u64(), 0xD076_4D4F_4476_689F);
        assert_eq!(r.next_u64(), 0x519E_4174_576F_3791);
        assert_eq!(r.next_u64(), 0xFBE0_7CFB_0C24_ED8C);
        assert_eq!(r.next_u64(), 0xB37D_9F60_0CD8_35B8);
        assert_eq!(hash2(7, 11), 0x0367_E40C_8937_23FC);

        let mut r = Rng::new(0x5EED);
        let mut idx4 = [0u8; 16];
        r.fill_state_indices(4, &mut idx4);
        assert_eq!(idx4, [0, 0, 2, 0, 0, 2, 2, 2, 1, 3, 1, 1, 1, 3, 3, 3]);

        let mut r = Rng::new(0x5EED);
        let mut idx3 = [0u8; 11];
        r.fill_state_indices(3, &mut idx3);
        assert_eq!(idx3, [0, 0, 2, 0, 0, 1, 2, 1, 1, 2, 1]);

        let mut r = Rng::new(0x5EED);
        let mut idx256 = [0u8; 8];
        r.fill_state_indices(256, &mut idx256);
        assert_eq!(idx256, [0, 12, 174, 36, 27, 135, 178, 142]);
    }

    #[test]
    fn bulk_fill_position_depends_only_on_len() {
        // a partial trailing block consumes exactly one u64; filling 11
        // indices advances the stream by ceil(11/8) = 2 draws
        let mut a = Rng::new(0x5EED);
        let mut buf = [0u8; 11];
        a.fill_state_indices(3, &mut buf);
        let mut b = Rng::new(0x5EED);
        b.next_u64();
        b.next_u64();
        let nxt = a.next_u64();
        assert_eq!(nxt, b.next_u64());
        assert_eq!(nxt, 0x1746_0BDF_1E7C_3333); // python cross-check

        // and slicing one fill as two fills of full blocks agrees
        let mut c = Rng::new(0x77);
        let mut one = [0u8; 16];
        c.fill_state_indices(4, &mut one);
        let mut d = Rng::new(0x77);
        let mut two = [0u8; 16];
        d.fill_state_indices(4, &mut two[..8]);
        d.fill_state_indices(4, &mut two[8..]);
        assert_eq!(one, two);
    }

    #[test]
    fn bulk_indices_bias_bound() {
        // The multiply-shift map sends each of the 256 byte values to an
        // index; per index the byte count deviates from 256/m by < 1,
        // i.e. per-index probability is within 2^-8 of 1/m — and the map
        // is exactly uniform whenever m divides 256.
        for m in 1..=256u32 {
            let mut cell = [0u32; 256];
            for b in 0..256u32 {
                cell[((b * m) >> 8) as usize] += 1;
            }
            for (k, &c) in cell.iter().enumerate().take(m as usize) {
                let dev = c as f64 - 256.0 / m as f64;
                assert!(dev.abs() < 1.0, "m={m} idx={k} count dev {dev}");
            }
            for (k, &c) in cell.iter().enumerate().skip(m as usize) {
                assert_eq!(c, 0, "m={m} produced out-of-range index {k}");
            }
            if 256 % m == 0 {
                let want = 256 / m;
                assert!(
                    cell.iter().take(m as usize).all(|&c| c == want),
                    "m={m} divides 256 but map is not exactly uniform"
                );
            }
        }
    }

    #[test]
    fn bulk_indices_chi_square() {
        // Chi-square of the sampled indices against the *exact*
        // multiply-shift cell distribution (uniform for m | 256).  The
        // seed is fixed, so thresholds are deterministic; they sit ~4
        // sigma above the chi-square mean.
        for &m in &[2usize, 3, 4, 256] {
            let mut cell = vec![0u64; m];
            for b in 0..256u32 {
                cell[((b * m as u32) >> 8) as usize] += 1;
            }
            let n = 200_000usize;
            let mut buf = vec![0u8; n];
            Rng::new(0xC0FFEE ^ m as u64).fill_state_indices(m as u32, &mut buf);
            let mut obs = vec![0u64; m];
            for &i in &buf {
                obs[i as usize] += 1;
            }
            let mut chi2 = 0.0f64;
            for k in 0..m {
                let e = n as f64 * cell[k] as f64 / 256.0;
                let d = obs[k] as f64 - e;
                chi2 += d * d / e;
            }
            let df = (m - 1) as f64;
            let limit = df + 4.0 * (2.0 * df).sqrt() + 8.0;
            assert!(chi2 < limit, "m={m} chi2={chi2} limit={limit}");
            assert!(obs.iter().all(|&c| c > 0), "m={m}: missing states");
        }
    }

    #[test]
    fn fill_normal_matches_pair_stream_and_moments() {
        // fill_normal consumes both Box–Muller values in order
        let mut a = Rng::new(31);
        let mut buf = [0.0f32; 5];
        a.fill_normal(&mut buf);
        let mut b = Rng::new(31);
        let (x0, x1) = b.normal_pair();
        let (x2, x3) = b.normal_pair();
        let (x4, _) = b.normal_pair();
        assert_eq!(buf, [x0, x1, x2, x3, x4]);

        let mut r = Rng::new(32);
        let n = 100_000;
        let mut big = vec![0.0f32; n];
        r.fill_normal(&mut big);
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for &x in &big {
            sum += x as f64;
            sq += (x as f64) * (x as f64);
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }
}
