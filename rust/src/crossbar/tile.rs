//! One crossbar tile: a dense block of analog cells with per-cell RTN
//! state sampled on every read.
//!
//! Hot path: `current_sum` is the innermost loop of the native simulator —
//! it draws one RTN state per (active row, column) cell per read, exactly
//! eq. (7)/(11).  Reads take `&self` and a caller-supplied [`Rng`], so a
//! programmed tile is immutable shared state: any number of threads can
//! read it concurrently, each with its own RNG stream (no allocation, no
//! shared RNG contention); the per-read noise term is `sigma_norm * c_l`
//! added to the normalised programmed weight.

use crate::device::state_offsets;
use crate::rng::Rng;

/// A (rows <= 256, cols <= 256) tile of programmed cells.
#[derive(Clone, Debug)]
pub struct Tile {
    /// Programmed weights normalised to full scale, row-major (rows, cols).
    w_norm: Vec<f32>,
    rows: usize,
    cols: usize,
    /// RTN state offsets `c_l` (zero-mean, unit-variance).
    offsets: Vec<f32>,
}

impl Tile {
    pub fn new(w_norm: Vec<f32>, rows: usize, cols: usize, num_states: usize) -> Self {
        assert_eq!(w_norm.len(), rows * cols);
        Tile {
            w_norm,
            rows,
            cols,
            offsets: state_offsets(num_states),
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn w_norm(&self) -> &[f32] {
        &self.w_norm
    }

    /// Analog current-sum read (original mode): for every column
    /// `out[c] += sum_r level[r] * (w_norm[r,c] + sigma_norm * c_state)`.
    ///
    /// Returns the accumulated cell-energy term
    /// `sum_{r,c} |w_norm[r,c]| * level[r]` (the caller multiplies by
    /// `E0 * rho`).
    pub fn current_sum(
        &self,
        levels: &[u32],
        out: &mut [f32],
        sigma_norm: f32,
        rng: &mut Rng,
    ) -> f64 {
        self.current_sum_scaled(levels, out, 1.0, sigma_norm, rng)
    }

    /// Current-sum with an output scale factor (used for bit-plane reads:
    /// `scale = 2^p`). `levels` are the DAC integer levels per row.
    pub fn current_sum_scaled(
        &self,
        levels: &[u32],
        out: &mut [f32],
        scale: f32,
        sigma_norm: f32,
        rng: &mut Rng,
    ) -> f64 {
        assert_eq!(levels.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        let m = self.offsets.len() as u32;
        let mut energy = 0.0f64;
        for r in 0..self.rows {
            let level = levels[r];
            if level == 0 {
                continue; // zero input drives no current
            }
            let lv = level as f32;
            let row = &self.w_norm[r * self.cols..(r + 1) * self.cols];
            let mut row_w_abs = 0.0f32;
            for (c, &w) in row.iter().enumerate() {
                // fresh RTN state per cell read (eq. 7)
                let state = rng.below(m) as usize;
                let noisy = w + sigma_norm * self.offsets[state];
                out[c] += scale * lv * noisy;
                row_w_abs += w.abs();
            }
            energy += (row_w_abs * lv) as f64;
        }
        energy
    }

    /// Noiseless reference read.
    pub fn current_sum_clean(&self, levels: &[u32], out: &mut [f32]) {
        for r in 0..self.rows {
            let lv = levels[r] as f32;
            if lv == 0.0 {
                continue;
            }
            let row = &self.w_norm[r * self.cols..(r + 1) * self.cols];
            for (c, &w) in row.iter().enumerate() {
                out[c] += lv * w;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_sigma_equals_clean() {
        let w = vec![0.5, -0.25, 0.125, 1.0];
        let t = Tile::new(w, 2, 2, 4);
        let levels = vec![3u32, 1];
        let mut noisy = vec![0.0f32; 2];
        let mut clean = vec![0.0f32; 2];
        let mut rng = Rng::new(1);
        t.current_sum(&levels, &mut noisy, 0.0, &mut rng);
        t.current_sum_clean(&levels, &mut clean);
        assert_eq!(noisy, clean);
    }

    #[test]
    fn zero_level_rows_skipped_and_free() {
        let w = vec![1.0; 4];
        let t = Tile::new(w, 2, 2, 4);
        let mut out = vec![0.0f32; 2];
        let mut rng = Rng::new(2);
        let e = t.current_sum(&[0, 0], &mut out, 0.5, &mut rng);
        assert_eq!(out, vec![0.0, 0.0]);
        assert_eq!(e, 0.0);
    }

    #[test]
    fn energy_counts_weight_times_level() {
        let w = vec![0.5, -0.5, 0.25, 0.25];
        let t = Tile::new(w, 2, 2, 1); // single state: noiseless
        let mut out = vec![0.0f32; 2];
        let mut rng = Rng::new(3);
        let e = t.current_sum(&[2, 4], &mut out, 0.0, &mut rng);
        // row0: (0.5+0.5)*2 = 2 ; row1: (0.25+0.25)*4 = 2
        assert!((e - 4.0).abs() < 1e-6);
    }

    #[test]
    fn noise_std_scales_with_sigma() {
        let cols = 4;
        let w = vec![0.0f32; cols]; // zero weights isolate the noise term
        let t = Tile::new(w, 1, cols, 4);
        let levels = vec![1u32];
        let mut rng = Rng::new(4);
        let spread = |t: &Tile, sigma: f32, rng: &mut Rng| {
            let trials = 4000;
            let mut sum = 0.0f64;
            let mut sq = 0.0f64;
            let mut out = vec![0.0f32; cols];
            for _ in 0..trials {
                out.fill(0.0);
                t.current_sum(&levels, &mut out, sigma, rng);
                for &o in &out {
                    sum += o as f64;
                    sq += (o as f64).powi(2);
                }
            }
            let n = (trials * cols) as f64;
            (sq / n - (sum / n).powi(2)).sqrt()
        };
        let s1 = spread(&t, 0.1, &mut rng);
        let s2 = spread(&t, 0.2, &mut rng);
        assert!((s2 / s1 - 2.0).abs() < 0.15, "ratio {}", s2 / s1);
    }
}
