//! One crossbar tile: a dense block of analog cells with per-cell RTN
//! state sampled on every read.
//!
//! Hot path: `current_sum_scaled` is the innermost loop of the native
//! simulator — it draws one RTN state per (active row, column) cell per
//! read, exactly eq. (7)/(11).  Reads take `&self` and a caller-supplied
//! [`Rng`], so a programmed tile is immutable shared state: any number of
//! threads can read it concurrently, each with its own RNG stream (no
//! allocation, no shared RNG contention); the per-read noise term is
//! `sigma_norm * c_l` added to the normalised programmed weight.
//!
//! # Kernel shape (PR 6, DESIGN.md §11)
//!
//! The read kernel is flat and branch-free so the compiler can
//! autovectorize it:
//!
//! 1. per active row, [`Rng::fill_state_indices`] bulk-samples one RTN
//!    state index per column (eight per `next_u64`, multiply-shift map)
//!    into a stack buffer — no per-cell rejection loop;
//! 2. a gather pass turns indices into `noise[c] = sigma_norm *
//!    offsets[idx]`;
//! 3. a fused accumulate over [`chunks_exact`](slice::chunks_exact)
//!    8-lanes computes `out[c] += scale * lv * (w[c] + noise[c])`;
//! 4. the analog energy term uses per-row `|w|` sums precomputed at
//!    [`Tile::new`], so energy accounting is O(rows) per read instead of
//!    O(rows·cols).
//!
//! Zero-level rows are skipped entirely (they drive no current, draw no
//! noise, and cost no energy), and a noiseless read (`sigma_norm == 0`
//! or a single-state device) consumes no RNG at all.
//!
//! [`Tile::current_sum_scaled_ref`] is the checked-in scalar reference:
//! the same noise stream and arithmetic in a naive per-cell loop.  It is
//! the bit-exactness oracle for the fused kernel in the test suite and
//! the denominator of the `kernel_vs_scalar_ratio` CI perf gate
//! (`benches/hotpath.rs`).
//!
//! # Programmed-weight plane cache (PR 9, DESIGN.md §13)
//!
//! Decomposed (bit-serial) reads drive every plane `p` with binary row
//! levels and scale the column current by `2^p`.  The weight side of
//! that product never changes after programming, so
//! [`Tile::with_plane_cache`] precomputes `w_scaled[p] = 2^p * w_norm`
//! once at program time and [`Tile::current_sum_plane`] reads the
//! cached plane with a per-state noise table `2^p * sigma_norm * c_l` —
//! two multiplies per cell become two adds.  Because `2^p` is a power
//! of two, IEEE-754 scaling by it is exact and commutes with rounding:
//! `fl(2^p*w + 2^p*nz) = 2^p * fl(w + nz)`, so cached-plane outputs and
//! energy are **bit-identical** to [`Tile::current_sum_scaled`] with
//! `scale = 2^p` (pinned by `plane_cache_matches_scaled_kernel`), and
//! the RNG stream is untouched (same per-active-row bulk draws).

use crate::device::state_offsets;
use crate::rng::Rng;

/// Widest tile the read kernel supports: the per-read index and noise
/// scratch are fixed-size stack buffers of this many lanes (matches
/// [`crate::crossbar::TILE_COLS`]).
pub const MAX_TILE_COLS: usize = 256;

/// A (rows <= 256, cols <= [`MAX_TILE_COLS`]) tile of programmed cells.
#[derive(Clone, Debug)]
pub struct Tile {
    /// Programmed weights normalised to full scale, row-major (rows, cols).
    w_norm: Vec<f32>,
    rows: usize,
    cols: usize,
    /// RTN state offsets `c_l` (zero-mean, unit-variance).
    offsets: Vec<f32>,
    /// Per-row `sum_c |w_norm[r, c]|`, precomputed at programming time:
    /// the cell-energy term of a read is `sum_r row_abs[r] * level[r]`
    /// (the |w| sum factors out of eq. 20), so energy accounting no
    /// longer walks every cell.
    row_abs: Vec<f32>,
    /// Cached weight-side bit-plane decomposition: `plane_bits`
    /// contiguous copies of `w_norm`, plane `p` pre-scaled by `2^p`
    /// (exact in IEEE-754).  Empty when the cache is not built
    /// ([`Tile::new`]); [`Tile::current_sum_plane`] falls back to the
    /// multiply-per-cell kernel for planes beyond `plane_bits`.
    w_planes: Vec<f32>,
    plane_bits: u32,
}

impl Tile {
    pub fn new(w_norm: Vec<f32>, rows: usize, cols: usize, num_states: usize) -> Self {
        Self::with_plane_cache(w_norm, rows, cols, num_states, 0)
    }

    /// Like [`Tile::new`], additionally precomputing the programmed-weight
    /// plane cache for decomposed reads of up to `plane_bits` activation
    /// bit-planes (`plane_bits = 0` skips the cache entirely).  Costs
    /// `plane_bits` extra copies of the tile's weights in memory; buys
    /// [`Tile::current_sum_plane`] a multiply-free inner loop.
    pub fn with_plane_cache(
        w_norm: Vec<f32>,
        rows: usize,
        cols: usize,
        num_states: usize,
        plane_bits: u32,
    ) -> Self {
        assert_eq!(w_norm.len(), rows * cols);
        assert!(cols <= MAX_TILE_COLS, "tile wider than the kernel lane buffer");
        let row_abs = if cols == 0 {
            vec![0.0; rows]
        } else {
            w_norm
                .chunks_exact(cols)
                .map(|row| row.iter().map(|w| w.abs()).sum())
                .collect()
        };
        let mut w_planes = Vec::with_capacity(plane_bits as usize * w_norm.len());
        for p in 0..plane_bits {
            let scale = (1u64 << p) as f32;
            w_planes.extend(w_norm.iter().map(|&w| scale * w));
        }
        Tile {
            w_norm,
            rows,
            cols,
            offsets: state_offsets(num_states),
            row_abs,
            w_planes,
            plane_bits,
        }
    }

    /// Activation bit-planes the programmed-weight cache covers.
    pub fn plane_bits(&self) -> u32 {
        self.plane_bits
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn w_norm(&self) -> &[f32] {
        &self.w_norm
    }

    /// Per-row `|w_norm|` sums (see [`Tile::new`]).
    pub fn row_abs(&self) -> &[f32] {
        &self.row_abs
    }

    /// Analog current-sum read (original mode): for every column
    /// `out[c] += sum_r level[r] * (w_norm[r,c] + sigma_norm * c_state)`.
    ///
    /// Returns the accumulated cell-energy term
    /// `sum_{r,c} |w_norm[r,c]| * level[r]` (the caller multiplies by
    /// `E0 * rho`).
    pub fn current_sum(
        &self,
        levels: &[u32],
        out: &mut [f32],
        sigma_norm: f32,
        rng: &mut Rng,
    ) -> f64 {
        self.current_sum_scaled(levels, out, 1.0, sigma_norm, rng)
    }

    /// Current-sum with an output scale factor (used for bit-plane reads:
    /// `scale = 2^p`). `levels` are the DAC integer levels per row.
    ///
    /// This is the fused SIMD-friendly kernel; see the module docs for
    /// the lane layout and [`Tile::current_sum_scaled_ref`] for the
    /// bit-identical scalar reference.
    pub fn current_sum_scaled(
        &self,
        levels: &[u32],
        out: &mut [f32],
        scale: f32,
        sigma_norm: f32,
        rng: &mut Rng,
    ) -> f64 {
        assert_eq!(levels.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        let cols = self.cols;
        let m = self.offsets.len() as u32;
        // noiseless reads (sigma 0, or a single-state device whose only
        // offset is 0) skip RTN sampling and consume no RNG
        let sample_noise = sigma_norm != 0.0 && m > 1;
        let mut idx = [0u8; MAX_TILE_COLS];
        let mut noise = [0.0f32; MAX_TILE_COLS];
        let mut energy = 0.0f64;
        for r in 0..self.rows {
            let level = levels[r];
            if level == 0 {
                continue; // zero input drives no current — and draws no noise
            }
            let lv = level as f32;
            let coef = scale * lv;
            let row = &self.w_norm[r * cols..(r + 1) * cols];
            if sample_noise {
                // fresh RTN state per cell read (eq. 7), bulk-sampled
                rng.fill_state_indices(m, &mut idx[..cols]);
                for (nz, &i) in noise[..cols].iter_mut().zip(&idx[..cols]) {
                    *nz = sigma_norm * self.offsets[i as usize];
                }
                // fused branch-free accumulate over 8-wide lanes
                let mut o8 = out.chunks_exact_mut(8);
                let mut w8 = row.chunks_exact(8);
                let mut n8 = noise[..cols].chunks_exact(8);
                for ((o, w), nz) in (&mut o8).zip(&mut w8).zip(&mut n8) {
                    for l in 0..8 {
                        o[l] += coef * (w[l] + nz[l]);
                    }
                }
                for ((o, &w), &nz) in o8
                    .into_remainder()
                    .iter_mut()
                    .zip(w8.remainder())
                    .zip(n8.remainder())
                {
                    *o += coef * (w + nz);
                }
            } else {
                for (o, &w) in out.iter_mut().zip(row) {
                    *o += coef * w;
                }
            }
            energy += (self.row_abs[r] * lv) as f64;
        }
        energy
    }

    /// Bit-plane read off the programmed-weight plane cache: binary row
    /// `levels` (one activation bit-plane), accumulating
    /// `out[c] += 2^p * (w_norm[r,c] + sigma_norm * c_state)` for every
    /// active row — bit-identical to [`Tile::current_sum_scaled`] with
    /// `scale = 2^p` on the same RNG stream (see the module docs for the
    /// exactness argument), but reading the pre-scaled plane
    /// `2^p * w_norm` and a pre-scaled per-state noise table instead of
    /// multiplying per cell.  Planes beyond the cache
    /// ([`Tile::plane_bits`]) fall back to the multiply kernel.
    ///
    /// Returns the same cell-energy term as the scaled kernel
    /// (`sum_r row_abs[r] * level[r]` — the output scale never enters
    /// the energy accounting).
    pub fn current_sum_plane(
        &self,
        levels: &[u32],
        out: &mut [f32],
        p: u32,
        sigma_norm: f32,
        rng: &mut Rng,
    ) -> f64 {
        if p >= self.plane_bits {
            let scale = (1u64 << p) as f32;
            return self.current_sum_scaled(levels, out, scale, sigma_norm, rng);
        }
        assert_eq!(levels.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        let cols = self.cols;
        let m = self.offsets.len() as u32;
        let sample_noise = sigma_norm != 0.0 && m > 1;
        let plane = &self.w_planes[p as usize * self.rows * cols..][..self.rows * cols];
        // per-state noise, pre-scaled by the plane weight: 2^p is exact,
        // so noisetab[i] == 2^p * (sigma_norm * offsets[i]) bitwise
        let plane_sigma = (1u64 << p) as f32 * sigma_norm;
        let mut noisetab = [0.0f32; 256];
        for (nt, &c) in noisetab.iter_mut().zip(&self.offsets) {
            *nt = plane_sigma * c;
        }
        let mut idx = [0u8; MAX_TILE_COLS];
        let mut noise = [0.0f32; MAX_TILE_COLS];
        let mut energy = 0.0f64;
        for r in 0..self.rows {
            let level = levels[r];
            if level == 0 {
                continue;
            }
            debug_assert_eq!(level, 1, "bit-plane levels are binary");
            let row = &plane[r * cols..(r + 1) * cols];
            if sample_noise {
                rng.fill_state_indices(m, &mut idx[..cols]);
                for (nz, &i) in noise[..cols].iter_mut().zip(&idx[..cols]) {
                    *nz = noisetab[i as usize];
                }
                // fused multiply-free accumulate over 8-wide lanes
                let mut o8 = out.chunks_exact_mut(8);
                let mut w8 = row.chunks_exact(8);
                let mut n8 = noise[..cols].chunks_exact(8);
                for ((o, w), nz) in (&mut o8).zip(&mut w8).zip(&mut n8) {
                    for l in 0..8 {
                        o[l] += w[l] + nz[l];
                    }
                }
                for ((o, &w), &nz) in o8
                    .into_remainder()
                    .iter_mut()
                    .zip(w8.remainder())
                    .zip(n8.remainder())
                {
                    *o += w + nz;
                }
            } else {
                for (o, &w) in out.iter_mut().zip(row) {
                    *o += w;
                }
            }
            energy += (self.row_abs[r] * level as f32) as f64;
        }
        energy
    }

    /// Checked-in scalar reference kernel: the *same* noise stream and
    /// arithmetic as [`Tile::current_sum_scaled`] (bulk per-row state
    /// indices, identical rounding), evaluated cell-by-cell with
    /// per-cell energy accumulation — the pre-PR-6 loop shape.
    ///
    /// Outputs and energy are bit-identical to the fused kernel (pinned
    /// by `fused_matches_scalar_reference`); only the speed differs.
    /// `benches/hotpath.rs` reports the fused/reference throughput ratio
    /// and CI gates on it regressing >15%.
    pub fn current_sum_scaled_ref(
        &self,
        levels: &[u32],
        out: &mut [f32],
        scale: f32,
        sigma_norm: f32,
        rng: &mut Rng,
    ) -> f64 {
        assert_eq!(levels.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        let cols = self.cols;
        let m = self.offsets.len() as u32;
        let sample_noise = sigma_norm != 0.0 && m > 1;
        let mut idx = [0u8; MAX_TILE_COLS];
        let mut energy = 0.0f64;
        for r in 0..self.rows {
            let level = levels[r];
            if level == 0 {
                continue;
            }
            let lv = level as f32;
            let coef = scale * lv;
            let row = &self.w_norm[r * cols..(r + 1) * cols];
            if sample_noise {
                rng.fill_state_indices(m, &mut idx[..cols]);
            }
            let mut row_w_abs = 0.0f32;
            for (c, &w) in row.iter().enumerate() {
                if sample_noise {
                    let nz = sigma_norm * self.offsets[idx[c] as usize];
                    out[c] += coef * (w + nz);
                } else {
                    out[c] += coef * w;
                }
                row_w_abs += w.abs();
            }
            energy += (row_w_abs * lv) as f64;
        }
        energy
    }

    /// Noiseless reference read.
    pub fn current_sum_clean(&self, levels: &[u32], out: &mut [f32]) {
        for r in 0..self.rows {
            let lv = levels[r] as f32;
            if lv == 0.0 {
                continue;
            }
            let row = &self.w_norm[r * self.cols..(r + 1) * self.cols];
            for (c, &w) in row.iter().enumerate() {
                out[c] += lv * w;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_sigma_equals_clean() {
        let w = vec![0.5, -0.25, 0.125, 1.0];
        let t = Tile::new(w, 2, 2, 4);
        let levels = vec![3u32, 1];
        let mut noisy = vec![0.0f32; 2];
        let mut clean = vec![0.0f32; 2];
        let mut rng = Rng::new(1);
        t.current_sum(&levels, &mut noisy, 0.0, &mut rng);
        t.current_sum_clean(&levels, &mut clean);
        assert_eq!(noisy, clean);
    }

    #[test]
    fn noiseless_reads_consume_no_rng() {
        // sigma 0 and m = 1 both skip sampling entirely
        let t4 = Tile::new(vec![1.0; 4], 2, 2, 4);
        let t1 = Tile::new(vec![1.0; 4], 2, 2, 1);
        let mut out = vec![0.0f32; 2];
        let mut rng = Rng::new(5);
        let before = rng.clone().next_u64();
        t4.current_sum(&[1, 1], &mut out, 0.0, &mut rng);
        t1.current_sum(&[1, 1], &mut out, 0.5, &mut rng);
        assert_eq!(rng.next_u64(), before);
    }

    #[test]
    fn zero_level_rows_skipped_and_free() {
        let w = vec![1.0; 4];
        let t = Tile::new(w, 2, 2, 4);
        let mut out = vec![0.0f32; 2];
        let mut rng = Rng::new(2);
        let e = t.current_sum(&[0, 0], &mut out, 0.5, &mut rng);
        assert_eq!(out, vec![0.0, 0.0]);
        assert_eq!(e, 0.0);
        // skipped rows also draw no noise: the stream did not advance
        let mut fresh = Rng::new(2);
        assert_eq!(rng.next_u64(), fresh.next_u64());
    }

    #[test]
    fn energy_counts_weight_times_level() {
        let w = vec![0.5, -0.5, 0.25, 0.25];
        let t = Tile::new(w, 2, 2, 1); // single state: noiseless
        let mut out = vec![0.0f32; 2];
        let mut rng = Rng::new(3);
        let e = t.current_sum(&[2, 4], &mut out, 0.0, &mut rng);
        // row0: (0.5+0.5)*2 = 2 ; row1: (0.25+0.25)*4 = 2
        assert!((e - 4.0).abs() < 1e-6);
    }

    #[test]
    fn row_abs_precomputed_at_program_time() {
        let w = vec![0.5, -0.5, 0.25, 0.25, -1.0, 0.0];
        let t = Tile::new(w, 3, 2, 4);
        assert_eq!(t.row_abs(), &[1.0, 0.5, 1.0]);
    }

    #[test]
    fn fused_matches_scalar_reference() {
        // the fused kernel and the checked-in scalar reference share one
        // noise stream and produce bit-identical outputs and energy —
        // this is the refreshed golden contract for the PR-6 stream
        let (rows, cols) = (5, 37); // odd width exercises remainder lanes
        let mut wr = Rng::new(100);
        for &m in &[2usize, 3, 4, 256] {
            let w: Vec<f32> = (0..rows * cols).map(|_| wr.normal() * 0.5).collect();
            let t = Tile::new(w, rows, cols, m);
            let levels: Vec<u32> = (0..rows as u32).map(|r| r % 4).collect();
            for &(scale, sigma) in &[(1.0f32, 0.2f32), (4.0, 0.05), (1.0, 0.0)] {
                let mut r1 = Rng::new(m as u64 + 7);
                let mut r2 = Rng::new(m as u64 + 7);
                let mut o1 = vec![0.0f32; cols];
                let mut o2 = vec![0.0f32; cols];
                let e1 = t.current_sum_scaled(&levels, &mut o1, scale, sigma, &mut r1);
                let e2 = t.current_sum_scaled_ref(&levels, &mut o2, scale, sigma, &mut r2);
                assert_eq!(o1, o2, "m={m} scale={scale} sigma={sigma}");
                assert_eq!(e1, e2, "m={m} energy");
                // both consumed the same stream
                assert_eq!(r1.next_u64(), r2.next_u64());
            }
        }
    }

    #[test]
    fn plane_cache_matches_scaled_kernel() {
        // the cached-plane kernel must be bit-identical — outputs, energy
        // AND RNG stream — to current_sum_scaled at scale 2^p, for every
        // cached plane, state count, and sigma (incl. the noiseless path);
        // planes beyond the cache take the fallback and must match too
        let (rows, cols) = (7, 37); // odd width exercises remainder lanes
        let plane_bits = 5u32;
        let mut wr = Rng::new(200);
        for &m in &[2usize, 3, 4, 256] {
            let w: Vec<f32> = (0..rows * cols).map(|_| wr.normal() * 0.5).collect();
            let cached = Tile::with_plane_cache(w.clone(), rows, cols, m, plane_bits);
            let plain = Tile::new(w, rows, cols, m);
            assert_eq!(cached.plane_bits(), plane_bits);
            assert_eq!(plain.plane_bits(), 0);
            // binary plane levels with zero rows mixed in
            let levels: Vec<u32> = (0..rows as u32).map(|r| r % 2).collect();
            for p in 0..plane_bits + 2 {
                for &sigma in &[0.2f32, 0.013, 0.0] {
                    let mut r1 = Rng::new(m as u64 * 31 + p as u64);
                    let mut r2 = r1.clone();
                    let mut o1 = vec![0.25f32; cols]; // non-zero accumulators
                    let mut o2 = o1.clone();
                    let e1 = cached.current_sum_plane(&levels, &mut o1, p, sigma, &mut r1);
                    let scale = (1u64 << p) as f32;
                    let e2 =
                        plain.current_sum_scaled(&levels, &mut o2, scale, sigma, &mut r2);
                    assert_eq!(o1, o2, "m={m} p={p} sigma={sigma}");
                    assert_eq!(e1, e2, "m={m} p={p} energy");
                    assert_eq!(r1.next_u64(), r2.next_u64(), "stream must match");
                }
            }
        }
    }

    #[test]
    fn plane_cache_prescales_weights_exactly() {
        let w = vec![0.5f32, -0.25, 0.125, 1.0, -1.0, 0.75];
        let t = Tile::with_plane_cache(w.clone(), 3, 2, 4, 3);
        // plane p is exactly 2^p * w_norm, contiguous and plane-major
        assert_eq!(t.w_planes.len(), 3 * w.len());
        for p in 0..3usize {
            for (i, &wv) in w.iter().enumerate() {
                assert_eq!(t.w_planes[p * w.len() + i], (1u64 << p) as f32 * wv);
            }
        }
    }

    #[test]
    fn noise_std_scales_with_sigma() {
        let cols = 4;
        let w = vec![0.0f32; cols]; // zero weights isolate the noise term
        let t = Tile::new(w, 1, cols, 4);
        let levels = vec![1u32];
        let mut rng = Rng::new(4);
        let spread = |t: &Tile, sigma: f32, rng: &mut Rng| {
            let trials = 4000;
            let mut sum = 0.0f64;
            let mut sq = 0.0f64;
            let mut out = vec![0.0f32; cols];
            for _ in 0..trials {
                out.fill(0.0);
                t.current_sum(&levels, &mut out, sigma, rng);
                for &o in &out {
                    sum += o as f64;
                    sq += (o as f64).powi(2);
                }
            }
            let n = (trials * cols) as f64;
            (sq / n - (sum / n).powi(2)).sqrt()
        };
        let s1 = spread(&t, 0.1, &mut rng);
        let s2 = spread(&t, 0.2, &mut rng);
        assert!((s2 / s1 - 2.0).abs() < 0.15, "ratio {}", s2 / s1);
    }
}
