//! Crossbar array simulator — the native device-level substrate.
//!
//! A weight matrix (K, N) is programmed over a grid of
//! [`TILE_ROWS`] x [`TILE_COLS`] tiles of analog cells; a MAC is a
//! "current sum" read: every row is driven by the DAC level of its
//! activation, every column accumulates `sum_k x_k * r_l(w_k, rho)`
//! (Fig 1c).  The simulator tracks analog energy, peripheral energy and
//! read cycles, and supports both read modes plus the baselines' read
//! schemes (multi-read averaging, binarized bit-slicing).
//!
//! **Ownership split (DESIGN.md):** a programmed [`CrossbarArray`] is
//! immutable shared state — every read path takes `&self`, RTN sampling
//! uses a caller-supplied [`Rng`], and energy/latency accounting
//! accumulates into a caller-owned [`ReadCounters`].  That makes arrays
//! `Send + Sync`, so one `Arc`'d array (or model) serves any number of
//! concurrent MAC streams with per-stream deterministic noise and
//! per-request energy attribution.
//!
//! The accuracy experiments of Tables 1–2 / Figs 9–11 run through the AOT
//! artifacts (XLA is far faster for full models; `--features aot`); this
//! module is the ground-truth device simulation used for microexperiments,
//! the hot-path bench, and cross-validation against the Pallas kernels.

pub mod tile;

pub use tile::Tile;

use crate::device::DeviceConfig;
use crate::energy::{LayerPlan, ReadMode, E0_PJ, E_ADC_PJ, E_DAC_PJ};
use crate::quant;
use crate::rng::Rng;

/// Crossbar tile rows (wordlines).
pub const TILE_ROWS: usize = 256;
/// Crossbar tile columns (bitlines).
pub const TILE_COLS: usize = 256;

/// Energy/latency accounting of a sequence of crossbar reads.
///
/// Owned by the caller (a request, a sample, a bench iteration — whatever
/// granularity the accounting needs), not by the array: the array itself
/// stays immutable and shareable.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ReadCounters {
    pub cell_pj: f64,
    pub peripheral_pj: f64,
    pub cycles: u64,
}

impl ReadCounters {
    pub fn total_pj(&self) -> f64 {
        self.cell_pj + self.peripheral_pj
    }

    pub fn merge(&mut self, other: &ReadCounters) {
        self.cell_pj += other.cell_pj;
        self.peripheral_pj += other.peripheral_pj;
        self.cycles += other.cycles;
    }

    /// Energy (uJ) accumulated since `prev`, an earlier snapshot of these
    /// counters — the per-layer/per-request attribution primitive the
    /// tracing subsystem uses.  Counters only ever grow, so the delta is
    /// non-negative for a genuine snapshot.
    pub fn uj_since(&self, prev: &ReadCounters) -> f64 {
        (self.total_pj() - prev.total_pj()) * 1e-6
    }
}

/// Reusable scratch for MAC reads: DAC level and bit-plane buffers.
///
/// One instance per execution stream (thread); reusing it across layers
/// and samples keeps the noisy forward path allocation-free.
///
/// `planes` is *plane-major*: `planes[p * rows + r]` is bit `p` of row
/// `r`'s DAC level, derived once per [`CrossbarArray::mac_scratch`] call
/// by [`quant::bit_planes_into`].  Decomposed mode then reads each
/// (plane, tile-row) as one contiguous slice — previously the bit-plane
/// of every row was re-derived per tile per plane, i.e. `tiles_x` times
/// too often on wide arrays.
#[derive(Clone, Debug, Default)]
pub struct MacScratch {
    levels: Vec<u32>,
    planes: Vec<u32>,
}

/// Reusable scratch for a *block* of samples read layer-major: one
/// [`MacScratch`] per image plus per-image activation scales and local
/// energy accumulators.  Owned by an execution stream (a rayon block
/// task / a pooled batch slab) and reused across layers and dispatches,
/// so the batched read path stays allocation-free at steady state.
#[derive(Clone, Debug, Default)]
pub struct MacScratchBlock {
    per_image: Vec<MacScratch>,
    act_scales: Vec<f32>,
    cell_pj: Vec<f64>,
    peri_pj: Vec<f64>,
}

impl MacScratchBlock {
    /// Grow to hold `n` images (never shrinks — capacity is the point).
    fn ensure(&mut self, n: usize) {
        if self.per_image.len() < n {
            self.per_image.resize_with(n, MacScratch::default);
        }
        if self.act_scales.len() < n {
            self.act_scales.resize(n, 0.0);
            self.cell_pj.resize(n, 0.0);
            self.peri_pj.resize(n, 0.0);
        }
    }
}

/// A (K, N) weight matrix programmed over crossbar tiles.
#[derive(Clone, Debug)]
pub struct CrossbarArray {
    pub rows: usize,
    pub cols: usize,
    tiles: Vec<Tile>,
    tiles_x: usize, // tiles along columns
    w_scale: f32,
    weight_bits: u32,
    /// Programming-time default energy coefficient — the fallback
    /// [`read_plan`](CrossbarArray::read_plan) rho when no serving
    /// [`EnergyPlan`](crate::energy::EnergyPlan) overrides it per read.
    pub rho: f32,
}

impl CrossbarArray {
    /// Program `weights` (row-major (K, N)) into tiles, quantising to the
    /// device's weight bits.
    pub fn program(weights: &[f32], rows: usize, cols: usize, cfg: &DeviceConfig) -> Self {
        assert_eq!(weights.len(), rows * cols, "weight shape mismatch");
        let (levels, w_scale) = quant::quant_weight(weights, cfg.weight_bits);
        let tiles_y = rows.div_ceil(TILE_ROWS);
        let tiles_x = cols.div_ceil(TILE_COLS);
        let mut tiles = Vec::with_capacity(tiles_y * tiles_x);
        let max_level = ((1i32 << (cfg.weight_bits - 1)) - 1) as f32;
        for ty in 0..tiles_y {
            for tx in 0..tiles_x {
                let r0 = ty * TILE_ROWS;
                let c0 = tx * TILE_COLS;
                let tr = TILE_ROWS.min(rows - r0);
                let tc = TILE_COLS.min(cols - c0);
                let mut norm = vec![0.0f32; tr * tc];
                for r in 0..tr {
                    for c in 0..tc {
                        norm[r * tc + c] =
                            levels[(r0 + r) * cols + (c0 + c)] as f32 / max_level;
                    }
                }
                // programmed-weight plane cache (PR 9): pre-scale each
                // activation bit-plane's weight copy at program time so
                // decomposed reads never re-derive 2^p * w per call —
                // bit-identical to the multiply kernel (tile.rs docs)
                tiles.push(Tile::with_plane_cache(
                    norm,
                    tr,
                    tc,
                    cfg.num_states,
                    cfg.act_bits,
                ));
            }
        }
        CrossbarArray {
            rows,
            cols,
            tiles,
            tiles_x,
            w_scale,
            weight_bits: cfg.weight_bits,
            rho: cfg.rho,
        }
    }

    pub fn w_scale(&self) -> f32 {
        self.w_scale
    }

    /// The programmed tiles (row-major tile grid).  Read-only: used to
    /// fold the exact programmed weight content into the result cache's
    /// model fingerprint (`server::model_fingerprint`).
    pub fn tiles(&self) -> &[Tile] {
        &self.tiles
    }

    /// Weight bits the array was programmed with.
    pub fn weight_bits(&self) -> u32 {
        self.weight_bits
    }

    /// Total programmed cells.
    pub fn num_cells(&self) -> usize {
        self.rows * self.cols
    }

    /// The array's default read plan: its programming-time rho (the
    /// layer's fallback when no [`EnergyPlan`](crate::energy::EnergyPlan)
    /// overrides it) at the given mode.
    pub fn read_plan(&self, mode: ReadMode) -> LayerPlan {
        LayerPlan::new(self.rho, mode)
    }

    /// One full-array MAC: `y[n] = sum_k x[k] * w~[k, n]` with fresh RTN
    /// samples per cell read (eq. 11).  `x` are raw activations; they are
    /// DAC-quantised to `act_bits` internally.  The read's energy
    /// coefficient and mode come from `plan` — the layer's entry of the
    /// serving [`EnergyPlan`](crate::energy::EnergyPlan), or
    /// [`CrossbarArray::read_plan`] for the programmed default.
    ///
    /// In `Original` mode this is a single analog read; in `Decomposed`
    /// mode (technique C) it is `act_bits` bit-plane reads with fresh
    /// fluctuation each cycle (eq. 15).
    ///
    /// Energy/cycle accounting accumulates into `counters`.  Convenience
    /// wrapper over [`CrossbarArray::mac_scratch`] that allocates a
    /// throwaway [`MacScratch`]; hot loops should hold one scratch per
    /// stream and call `mac_scratch` directly.
    #[allow(clippy::too_many_arguments)]
    pub fn mac(
        &self,
        x: &[f32],
        out: &mut [f32],
        plan: LayerPlan,
        act_bits: u32,
        intensity: f32,
        rng: &mut Rng,
        counters: &mut ReadCounters,
    ) {
        let mut scratch = MacScratch::default();
        self.mac_scratch(x, out, plan, act_bits, intensity, rng, counters, &mut scratch);
    }

    /// Allocation-free MAC: like [`CrossbarArray::mac`] but reusing a
    /// caller-owned scratch for the DAC levels and bit-plane buffers.
    #[allow(clippy::too_many_arguments)]
    pub fn mac_scratch(
        &self,
        x: &[f32],
        out: &mut [f32],
        plan: LayerPlan,
        act_bits: u32,
        intensity: f32,
        rng: &mut Rng,
        counters: &mut ReadCounters,
        scratch: &mut MacScratch,
    ) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        out.fill(0.0);
        let act_scale = quant::quant_act_into(x, act_bits, &mut scratch.levels);
        let sigma_norm = plan.sigma_rel(intensity); // vs full-scale
        let rho = plan.rho;
        let mode = plan.mode;
        let w_scale = self.w_scale;
        let tiles_x = self.tiles_x;

        let mut cell_pj = 0.0f64;
        let mut peri_pj = 0.0f64;
        let mut cycles = 0u64;

        match mode {
            ReadMode::Original => {
                for (ti, t) in self.tiles.iter().enumerate() {
                    let (ty, tx) = (ti / tiles_x, ti % tiles_x);
                    let r0 = ty * TILE_ROWS;
                    let c0 = tx * TILE_COLS;
                    let lv = &scratch.levels[r0..r0 + t.rows()];
                    let e = t.current_sum(
                        lv,
                        &mut out[c0..c0 + t.cols()],
                        sigma_norm,
                        rng,
                    );
                    // analog cell energy: rho * |w|_norm * level per cell
                    cell_pj += E0_PJ * rho as f64 * e;
                    peri_pj += t.rows() as f64 * E_DAC_PJ + t.cols() as f64 * E_ADC_PJ;
                }
                cycles += 1;
            }
            ReadMode::Decomposed => {
                // derive all bit-planes once, plane-major (see MacScratch)
                quant::bit_planes_into(&scratch.levels, act_bits, &mut scratch.planes);
                let rows_total = self.rows;
                for p in 0..act_bits {
                    let plane = &scratch.planes
                        [p as usize * rows_total..(p as usize + 1) * rows_total];
                    for (ti, t) in self.tiles.iter().enumerate() {
                        let (ty, tx) = (ti / tiles_x, ti % tiles_x);
                        let r0 = ty * TILE_ROWS;
                        let c0 = tx * TILE_COLS;
                        // cached-plane kernel: reads 2^p * w_norm prepared
                        // at program time (falls back past plane_bits)
                        let e = t.current_sum_plane(
                            &plane[r0..r0 + t.rows()],
                            &mut out[c0..c0 + t.cols()],
                            p,
                            sigma_norm,
                            rng,
                        );
                        cell_pj += E0_PJ * rho as f64 * e;
                        peri_pj +=
                            t.rows() as f64 * E_DAC_PJ + t.cols() as f64 * E_ADC_PJ;
                    }
                    cycles += 1;
                }
            }
        }
        // de-normalise: levels * act_scale, cells were stored / w_scale
        for v in out.iter_mut() {
            *v *= act_scale * w_scale;
        }
        counters.cell_pj += cell_pj;
        counters.peripheral_pj += peri_pj;
        counters.cycles += cycles;
    }

    /// Layer-major batched MAC: reads a whole block of samples through
    /// this array with a **tile-outer, image-inner** sweep, so each
    /// tile's `w_norm` / plane cache is streamed from memory once per
    /// block instead of once per image.  `xs` is `n * rows` row-major
    /// samples, `outs` is `n * cols`; image `i` draws RTN noise from
    /// `rngs[i]` and accounts energy/cycles into `counters[i]`.
    ///
    /// **Bit-identity contract:** for every image `i`, the RNG draw
    /// order (tile order; Decomposed: plane-outer, tile-inner), the f32
    /// output accumulation order, and the f64 energy accumulation order
    /// are exactly those of a solo [`CrossbarArray::mac_scratch`] call
    /// on `(xs_i, rngs[i], counters[i])` — outputs and counters are
    /// bitwise identical to the sample-major path (pinned by tests).
    /// Interleaving images *between* tiles is safe because images touch
    /// disjoint output rows and private RNG/counter state.
    #[allow(clippy::too_many_arguments)]
    pub fn mac_scratch_block(
        &self,
        xs: &[f32],
        outs: &mut [f32],
        plan: LayerPlan,
        act_bits: u32,
        intensity: f32,
        rngs: &mut [Rng],
        counters: &mut [ReadCounters],
        block: &mut MacScratchBlock,
    ) {
        let n = rngs.len();
        assert_eq!(xs.len(), n * self.rows);
        assert_eq!(outs.len(), n * self.cols);
        assert_eq!(counters.len(), n);
        block.ensure(n);
        let sigma_norm = plan.sigma_rel(intensity);
        let rho = plan.rho;
        let mode = plan.mode;
        let w_scale = self.w_scale;
        let tiles_x = self.tiles_x;
        let rows = self.rows;
        let cols = self.cols;

        // per-image prologue: zero outputs, DAC-quantise activations.
        // No RNG is consumed here, same as the solo path.
        for i in 0..n {
            outs[i * cols..(i + 1) * cols].fill(0.0);
            block.act_scales[i] = quant::quant_act_into(
                &xs[i * rows..(i + 1) * rows],
                act_bits,
                &mut block.per_image[i].levels,
            );
            block.cell_pj[i] = 0.0;
            block.peri_pj[i] = 0.0;
        }

        match mode {
            ReadMode::Original => {
                for (ti, t) in self.tiles.iter().enumerate() {
                    let (ty, tx) = (ti / tiles_x, ti % tiles_x);
                    let r0 = ty * TILE_ROWS;
                    let c0 = tx * TILE_COLS;
                    let peri = t.rows() as f64 * E_DAC_PJ + t.cols() as f64 * E_ADC_PJ;
                    for i in 0..n {
                        let lv = &block.per_image[i].levels[r0..r0 + t.rows()];
                        let out = &mut outs[i * cols + c0..i * cols + c0 + t.cols()];
                        let e = t.current_sum(lv, out, sigma_norm, &mut rngs[i]);
                        block.cell_pj[i] += E0_PJ * rho as f64 * e;
                        block.peri_pj[i] += peri;
                    }
                }
            }
            ReadMode::Decomposed => {
                for i in 0..n {
                    let s = &mut block.per_image[i];
                    quant::bit_planes_into(&s.levels, act_bits, &mut s.planes);
                }
                for p in 0..act_bits {
                    for (ti, t) in self.tiles.iter().enumerate() {
                        let (ty, tx) = (ti / tiles_x, ti % tiles_x);
                        let r0 = ty * TILE_ROWS;
                        let c0 = tx * TILE_COLS;
                        let peri =
                            t.rows() as f64 * E_DAC_PJ + t.cols() as f64 * E_ADC_PJ;
                        for i in 0..n {
                            let plane = &block.per_image[i].planes
                                [p as usize * rows..(p as usize + 1) * rows];
                            let out =
                                &mut outs[i * cols + c0..i * cols + c0 + t.cols()];
                            let e = t.current_sum_plane(
                                &plane[r0..r0 + t.rows()],
                                out,
                                p,
                                sigma_norm,
                                &mut rngs[i],
                            );
                            block.cell_pj[i] += E0_PJ * rho as f64 * e;
                            block.peri_pj[i] += peri;
                        }
                    }
                }
            }
        }

        // per-image epilogue: de-normalise and flush the local
        // accumulators, exactly once per image like the solo path.
        let cycles = match mode {
            ReadMode::Original => 1u64,
            ReadMode::Decomposed => act_bits as u64,
        };
        for i in 0..n {
            let s = block.act_scales[i] * w_scale;
            for v in outs[i * cols..(i + 1) * cols].iter_mut() {
                *v *= s;
            }
            counters[i].cell_pj += block.cell_pj[i];
            counters[i].peripheral_pj += block.peri_pj[i];
            counters[i].cycles += cycles;
        }
    }

    /// Noiseless reference MAC (for error measurements).
    pub fn mac_clean(&self, x: &[f32], out: &mut [f32], act_bits: u32) {
        let (levels, act_scale) = quant::quant_act(x, act_bits);
        out.fill(0.0);
        for (ti, t) in self.tiles.iter().enumerate() {
            let (ty, tx) = (ti / self.tiles_x, ti % self.tiles_x);
            let r0 = ty * TILE_ROWS;
            let c0 = tx * TILE_COLS;
            t.current_sum_clean(&levels[r0..r0 + t.rows()], &mut out[c0..c0 + t.cols()]);
        }
        for v in out.iter_mut() {
            *v *= act_scale * self.w_scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DeviceConfig {
        DeviceConfig::default()
    }

    fn randw(seed: u64, n: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() * 0.5).collect()
    }

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn programmed_array_is_shareable() {
        // the whole point of the ownership split: programmed arrays are
        // plain immutable data, safe to share across engine threads.
        assert_send_sync::<CrossbarArray>();
        assert_send_sync::<Tile>();
        assert_send_sync::<ReadCounters>();
    }

    #[test]
    fn clean_mac_matches_quantised_matmul() {
        let (k, n) = (64, 32);
        let w = randw(1, k * n);
        let arr = CrossbarArray::program(&w, k, n, &cfg());
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
        let mut out = vec![0.0f32; n];
        arr.mac_clean(&x, &mut out, 5);
        // reference: quantised x @ quantised w
        let (xl, xs) = quant::quant_act(&x, 5);
        let (wl, ws) = quant::quant_weight(&w, 8);
        let maxw = 127.0;
        for c in 0..n {
            let want: f32 = (0..k)
                .map(|r| xl[r] as f32 * xs * (wl[r * n + c] as f32 / maxw * ws))
                .sum();
            assert!((out[c] - want).abs() < 1e-3, "col {c}: {} vs {want}", out[c]);
        }
    }

    #[test]
    fn noisy_mac_centered_on_clean() {
        let (k, n) = (128, 16);
        let w = randw(3, k * n);
        let arr = CrossbarArray::program(&w, k, n, &cfg());
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
        let mut clean = vec![0.0f32; n];
        arr.mac_clean(&x, &mut clean, 5);
        let trials = 200;
        let mut mean = vec![0.0f64; n];
        let mut out = vec![0.0f32; n];
        let mut counters = ReadCounters::default();
        for _ in 0..trials {
            let plan = arr.read_plan(ReadMode::Original);
            arr.mac(&x, &mut out, plan, 5, 1.0, &mut rng, &mut counters);
            for (m, &o) in mean.iter_mut().zip(out.iter()) {
                *m += o as f64 / trials as f64;
            }
        }
        for c in 0..n {
            assert!(
                (mean[c] - clean[c] as f64).abs() < 0.1 * (clean[c].abs() as f64 + 1.0),
                "col {c}: mean {} clean {}",
                mean[c],
                clean[c]
            );
        }
    }

    #[test]
    fn decomposed_lower_std_than_original() {
        // eq (18) at the array level
        let (k, n) = (96, 8);
        let w = randw(5, k * n);
        let mut arr = CrossbarArray::program(&w, k, n, &cfg());
        arr.rho = 0.3; // strong noise so the effect is clear
        let mut rng = Rng::new(6);
        let x: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
        let trials = 300;
        let mut out = vec![0.0f32; n];
        let mut spread = |arr: &CrossbarArray, mode, rng: &mut Rng| {
            let mut counters = ReadCounters::default();
            let mut sum = vec![0.0f64; n];
            let mut sq = vec![0.0f64; n];
            for _ in 0..trials {
                arr.mac(&x, &mut out, arr.read_plan(mode), 5, 1.0, rng, &mut counters);
                for c in 0..n {
                    sum[c] += out[c] as f64;
                    sq[c] += (out[c] as f64).powi(2);
                }
            }
            (0..n)
                .map(|c| {
                    let m = sum[c] / trials as f64;
                    (sq[c] / trials as f64 - m * m).max(0.0).sqrt()
                })
                .sum::<f64>()
                / n as f64
        };
        let s_ori = spread(&arr, ReadMode::Original, &mut rng);
        let s_dec = spread(&arr, ReadMode::Decomposed, &mut rng);
        assert!(
            s_dec < s_ori,
            "decomposed std {s_dec} must be < original {s_ori}"
        );
    }

    #[test]
    fn decomposed_lower_cell_energy() {
        // eq (20) at the array level
        let (k, n) = (64, 8);
        let w = randw(7, k * n);
        let mut rng = Rng::new(8);
        let x: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
        let mut out = vec![0.0f32; n];

        let arr = CrossbarArray::program(&w, k, n, &cfg());
        let mut c1 = ReadCounters::default();
        arr.mac(&x, &mut out, arr.read_plan(ReadMode::Original), 5, 1.0, &mut rng, &mut c1);
        let mut c2 = ReadCounters::default();
        arr.mac(&x, &mut out, arr.read_plan(ReadMode::Decomposed), 5, 1.0, &mut rng, &mut c2);
        assert!(c2.cell_pj < c1.cell_pj);
        // ... at the cost of more cycles and peripheral energy
        assert!(c2.cycles > c1.cycles);
        assert!(c2.peripheral_pj > c1.peripheral_pj);
    }

    #[test]
    fn mac_scratch_matches_mac() {
        // the allocation-free path is bit-identical to the wrapper
        let (k, n) = (96, 24);
        let w = randw(12, k * n);
        let arr = CrossbarArray::program(&w, k, n, &cfg());
        let x: Vec<f32> = {
            let mut rx = Rng::new(14);
            (0..k).map(|_| rx.next_f32()).collect()
        };
        let mut r1 = Rng::new(13);
        let mut r2 = Rng::new(13);
        let mut scratch = MacScratch::default();
        for mode in [ReadMode::Original, ReadMode::Decomposed] {
            let (mut o1, mut o2) = (vec![0.0f32; n], vec![0.0f32; n]);
            let mut c1 = ReadCounters::default();
            let mut c2 = ReadCounters::default();
            arr.mac(&x, &mut o1, arr.read_plan(mode), 5, 1.0, &mut r1, &mut c1);
            let plan = arr.read_plan(mode);
            arr.mac_scratch(&x, &mut o2, plan, 5, 1.0, &mut r2, &mut c2, &mut scratch);
            assert_eq!(o1, o2);
            assert_eq!(c1, c2);
        }
    }

    #[test]
    fn noisy_parity_across_tile_boundaries() {
        // multi-tile shapes exercise the plane-major scratch slicing per
        // (plane, tile): mac and mac_scratch must stay bit-identical in
        // both modes, and repeated same-seed reads must reproduce
        let (k, n) = (TILE_ROWS + 13, TILE_COLS + 9);
        let w = randw(31, k * n);
        let arr = CrossbarArray::program(&w, k, n, &cfg());
        let x: Vec<f32> = {
            let mut rx = Rng::new(32);
            (0..k).map(|_| rx.next_f32()).collect()
        };
        let mut scratch = MacScratch::default();
        for mode in [ReadMode::Original, ReadMode::Decomposed] {
            let (mut o1, mut o2) = (vec![0.0f32; n], vec![0.0f32; n]);
            let mut c1 = ReadCounters::default();
            let mut c2 = ReadCounters::default();
            let mut r1 = Rng::new(33);
            let mut r2 = Rng::new(33);
            arr.mac(&x, &mut o1, arr.read_plan(mode), 5, 1.0, &mut r1, &mut c1);
            let plan = arr.read_plan(mode);
            arr.mac_scratch(&x, &mut o2, plan, 5, 1.0, &mut r2, &mut c2, &mut scratch);
            assert_eq!(o1, o2);
            assert_eq!(c1, c2);
            assert!(o1.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn decomposed_fallback_past_cached_planes_is_bit_identical() {
        // two arrays over the same weights, one whose plane cache covers
        // only 3 of the 5 read planes (program-time act_bits 3) and one
        // fully cached: the fallback for planes 3..5 must leave outputs
        // and counters bit-identical, on the same RNG stream
        let (k, n) = (96, 24);
        let w = randw(41, k * n);
        let cfg_small = DeviceConfig {
            act_bits: 3,
            ..cfg()
        };
        let cfg_big = DeviceConfig {
            act_bits: 7,
            ..cfg()
        };
        let a_small = CrossbarArray::program(&w, k, n, &cfg_small);
        let a_big = CrossbarArray::program(&w, k, n, &cfg_big);
        let x: Vec<f32> = {
            let mut rx = Rng::new(42);
            (0..k).map(|_| rx.next_f32()).collect()
        };
        let (mut o1, mut o2) = (vec![0.0f32; n], vec![0.0f32; n]);
        let mut c1 = ReadCounters::default();
        let mut c2 = ReadCounters::default();
        let mut r1 = Rng::new(43);
        let mut r2 = Rng::new(43);
        let plan = a_small.read_plan(ReadMode::Decomposed);
        a_small.mac(&x, &mut o1, plan, 5, 1.0, &mut r1, &mut c1);
        a_big.mac(&x, &mut o2, plan, 5, 1.0, &mut r2, &mut c2);
        assert_eq!(o1, o2, "fallback planes diverged from cached planes");
        assert_eq!(c1, c2);
        assert_eq!(r1.next_u64(), r2.next_u64());
    }

    #[test]
    fn block_read_matches_solo_reads_bitwise() {
        // the layer-major block entry point must reproduce, per image,
        // exactly the outputs, counters and RNG stream of a solo
        // mac_scratch call — across tile boundaries and in both modes
        let (k, n) = (TILE_ROWS + 13, TILE_COLS + 9);
        let w = randw(51, k * n);
        let arr = CrossbarArray::program(&w, k, n, &cfg());
        let imgs = 5usize;
        let xs: Vec<f32> = {
            let mut rx = Rng::new(52);
            (0..imgs * k).map(|_| rx.next_f32()).collect()
        };
        let mut block = MacScratchBlock::default();
        for mode in [ReadMode::Original, ReadMode::Decomposed] {
            let plan = arr.read_plan(mode);
            // solo reference, one image at a time
            let mut solo_out = vec![0.0f32; imgs * n];
            let mut solo_c = vec![ReadCounters::default(); imgs];
            let mut solo_rngs: Vec<Rng> =
                (0..imgs).map(|i| Rng::stream(53, i as u64)).collect();
            let mut scratch = MacScratch::default();
            for i in 0..imgs {
                arr.mac_scratch(
                    &xs[i * k..(i + 1) * k],
                    &mut solo_out[i * n..(i + 1) * n],
                    plan,
                    5,
                    1.0,
                    &mut solo_rngs[i],
                    &mut solo_c[i],
                    &mut scratch,
                );
            }
            // blocked layer-major read
            let mut blk_out = vec![0.0f32; imgs * n];
            let mut blk_c = vec![ReadCounters::default(); imgs];
            let mut blk_rngs: Vec<Rng> =
                (0..imgs).map(|i| Rng::stream(53, i as u64)).collect();
            arr.mac_scratch_block(
                &xs,
                &mut blk_out,
                plan,
                5,
                1.0,
                &mut blk_rngs,
                &mut blk_c,
                &mut block,
            );
            assert_eq!(solo_out, blk_out, "{mode:?} outputs diverged");
            assert_eq!(solo_c, blk_c, "{mode:?} counters diverged");
            for (a, b) in solo_rngs.iter_mut().zip(blk_rngs.iter_mut()) {
                assert_eq!(a.next_u64(), b.next_u64(), "{mode:?} RNG stream");
            }
        }
    }

    #[test]
    fn counters_are_caller_owned_and_mergeable() {
        let (k, n) = (32, 8);
        let w = randw(21, k * n);
        let arr = CrossbarArray::program(&w, k, n, &cfg());
        let mut rng = Rng::new(22);
        let x: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
        let mut out = vec![0.0f32; n];
        let mut a = ReadCounters::default();
        let mut b = ReadCounters::default();
        arr.mac(&x, &mut out, arr.read_plan(ReadMode::Original), 5, 1.0, &mut rng, &mut a);
        arr.mac(&x, &mut out, arr.read_plan(ReadMode::Original), 5, 1.0, &mut rng, &mut b);
        let mut merged = a;
        merged.merge(&b);
        assert_eq!(merged.cycles, 2);
        assert!((merged.total_pj() - (a.total_pj() + b.total_pj())).abs() < 1e-12);
    }

    #[test]
    fn tiling_covers_odd_shapes() {
        let (k, n) = (TILE_ROWS + 37, TILE_COLS + 5);
        let w = randw(9, k * n);
        let arr = CrossbarArray::program(&w, k, n, &cfg());
        assert_eq!(arr.num_cells(), k * n);
        let x = vec![0.5f32; k];
        let mut out = vec![0.0f32; n];
        arr.mac_clean(&x, &mut out, 5);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn higher_rho_less_noise_more_energy() {
        let (k, n) = (128, 8);
        let w = randw(10, k * n);
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
        let mut out = vec![0.0f32; n];
        let mut run = |rho: f32, rng: &mut Rng| {
            let mut arr = CrossbarArray::program(&w, k, n, &cfg());
            arr.rho = rho;
            let mut clean = vec![0.0f32; n];
            arr.mac_clean(&x, &mut clean, 5);
            let trials = 100;
            let mut err = 0.0f64;
            let mut counters = ReadCounters::default();
            for _ in 0..trials {
                let plan = arr.read_plan(ReadMode::Original);
                arr.mac(&x, &mut out, plan, 5, 1.0, rng, &mut counters);
                err += out
                    .iter()
                    .zip(clean.iter())
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>();
            }
            (err, counters.cell_pj)
        };
        let (err_lo, e_lo) = run(0.5, &mut rng);
        let (err_hi, e_hi) = run(8.0, &mut rng);
        assert!(err_hi < err_lo, "noise must fall with rho");
        assert!(e_hi > e_lo, "energy must rise with rho");
    }
}
