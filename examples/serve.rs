//! Serving example: the dynamic-batching inference router over the NATIVE
//! crossbar engine — one immutable `Arc<NoisyModel>` shared by a pool of
//! batch workers (each batch additionally fans across rayon), driven by
//! concurrent client threads.  Reports throughput, queueing latency,
//! batch fill, and per-request device energy.
//!
//!     cargo run --release --example serve -- --requests 512 --clients 8 --workers 2

use std::sync::Arc;

use emtopt::coordinator::router::{serve_native, NativeServerConfig};
use emtopt::data::{Dataset, Split, Suite};
use emtopt::device::DeviceConfig;
use emtopt::inference::template_classifier;
use emtopt::util::cli::Args;

fn main() -> emtopt::Result<()> {
    let args = Args::parse()?;
    let requests: u32 = args.parse_or("requests", 256)?;
    let clients: usize = args.parse_or("clients", 8)?;
    let workers: usize = args.parse_or("workers", 2)?;

    let dev = DeviceConfig::default();
    let dataset = Dataset::new(Suite::Cifar, emtopt::data::DATA_SEED);
    // the deployed model: nearest-template classifier programmed on a
    // crossbar (real accuracy, no AOT training stack needed)
    let model = Arc::new(template_classifier(&dataset, &dev)?);
    println!(
        "deploying template classifier ({} cells) on {workers} engine workers",
        model.num_cells()
    );

    let server_cfg = NativeServerConfig {
        workers,
        device: dev,
        ..Default::default()
    };
    let batch = server_cfg.batch;
    let (client, stats, engines) = serve_native(model, server_cfg)?;

    println!("serving {requests} requests from {clients} clients");
    let t0 = std::time::Instant::now();
    let per = (requests as usize).div_ceil(clients);
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let cl = client.clone();
            let ds = dataset.clone();
            std::thread::spawn(move || {
                let mut ok = 0u32;
                let mut correct = 0u32;
                for i in 0..per {
                    let idx = (c * per + i) as u64;
                    let mut img = vec![0.0f32; emtopt::data::IMG_LEN];
                    let label = ds.sample_into(Split::Test, idx, &mut img);
                    if let Ok(pred) = cl.classify(img) {
                        ok += 1;
                        if pred == label as usize {
                            correct += 1;
                        }
                    }
                }
                (ok, correct)
            })
        })
        .collect();
    let (mut ok, mut correct) = (0u32, 0u32);
    for h in handles {
        let (o, c) = h.join().unwrap();
        ok += o;
        correct += c;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{ok} ok / {} sent in {dt:.2}s -> {:.0} req/s",
        per * clients,
        ok as f64 / dt
    );
    println!(
        "accuracy on served traffic: {:.1}% | mean queue {:.2} ms | \
         mean infer {:.2} ms/batch | batch fill {:.0}% | {:.1} nJ/request",
        100.0 * correct as f64 / ok.max(1) as f64,
        stats.mean_queue_us() / 1000.0,
        stats.mean_infer_us() / 1000.0,
        stats.mean_batch_fill(batch) * 100.0,
        stats.mean_energy_pj_per_request() / 1000.0
    );
    drop(client);
    for h in engines {
        h.join().ok();
    }
    Ok(())
}
