//! Serving example: the full network path — HTTP clients over real TCP
//! sockets -> epoll event loop -> per-tier bounded queues -> unified
//! scheduler (one shared work-stealing worker pool over one immutable
//! `Arc<NoisyModel>`).
//!
//! Boots `emtopt::server::serve_http` on an ephemeral localhost port,
//! drives it with the open-loop load generator (keep-alive connections,
//! mixed energy tiers by default), then prints the client-side report
//! next to the server-side per-tier stats — the energy-accuracy knob of
//! the paper (rho per tier) shows up directly in nJ/request.
//!
//!     cargo run --release --example serve -- --requests 512 --connections 8 --workers 2
//!
//! Flags: --requests N (512) --connections N (8) --workers N (2)
//!        --qps F (0 = closed loop) --tier low|normal|high|mixed (mixed)
//!        --batch N (1) — images per request body; >1 drives the
//!        multi-image {"images": ...} batch path end to end

use std::sync::Arc;

use emtopt::coordinator::router::NativeServerConfig;
use emtopt::data::{Dataset, Suite};
use emtopt::device::DeviceConfig;
use emtopt::inference::template_classifier;
use emtopt::server::loadgen::{self, LoadgenConfig};
use emtopt::server::{parse_tier_arg, serve_http, HttpServerConfig};
use emtopt::util::cli::Args;

fn main() -> emtopt::Result<()> {
    let args = Args::parse()?;
    let requests: u64 = args.parse_or("requests", 512)?;
    let connections: usize = args.parse_or("connections", 8)?;
    let workers: usize = args.parse_or("workers", 2)?;
    let qps: f64 = args.parse_or("qps", 0.0)?;
    let batch: usize = args.parse_or("batch", 1)?;
    let tier_arg = args.str_or("tier", "mixed");
    let tier = parse_tier_arg(&tier_arg)?;

    let dev = DeviceConfig::default();
    let dataset = Dataset::new(Suite::Cifar, emtopt::data::DATA_SEED);
    // the deployed model: nearest-template classifier programmed on a
    // crossbar (real accuracy, no AOT training stack needed)
    let model = Arc::new(template_classifier(&dataset, &dev)?);
    println!(
        "deploying template classifier ({} cells) behind HTTP, {workers} shared workers",
        model.num_cells()
    );

    let handle = serve_http(
        model,
        HttpServerConfig {
            addr: "127.0.0.1:0".into(), // ephemeral port
            engine: NativeServerConfig {
                workers,
                device: dev,
                ..Default::default()
            },
            ..Default::default()
        },
    )?;
    println!("listening on http://{}", handle.addr());
    for (plan, _) in handle.per_tier() {
        println!("  {}", plan.describe());
    }

    println!(
        "\nloadgen: {requests} requests over {connections} TCP connections \
         (tier {tier_arg}, {batch} images/request)"
    );
    let report = loadgen::run(&LoadgenConfig {
        addr: handle.addr().to_string(),
        connections,
        requests,
        target_qps: qps,
        tier,
        classify: true,
        batch,
        ..Default::default()
    })?;
    println!("{}", report.render());

    println!("\nserver side:");
    print!("{}", handle.tier_summary());
    handle.shutdown()
}
