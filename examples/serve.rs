//! Serving example: the dynamic-batching inference router in front of the
//! noisy in-memory model, driven by concurrent client threads — reports
//! throughput, queueing latency, and batch fill.
//!
//!     cargo run --release --example serve -- --requests 512 --clients 8

use emtopt::coordinator::router::{serve, ServerConfig};
use emtopt::coordinator::{self, store, Solution};
use emtopt::data::{Dataset, Split, Suite};
use emtopt::util::cli::Args;

fn main() -> emtopt::Result<()> {
    let args = Args::parse()?;
    let requests: u32 = args.parse_or("requests", 256)?;
    let clients: usize = args.parse_or("clients", 8)?;
    let model_key = args.str_or("model", "mlp_10");

    // train (or load) the A+B model that gets deployed
    let trained = {
        let arts = emtopt::runtime::Artifacts::open_default()?;
        let cfg = coordinator::experiments::schedule_for(&model_key);
        store::train_cached(&arts, &model_key, Suite::Cifar, Solution::AB, &cfg)?
    };

    let (client, stats, engine) = serve(trained, ServerConfig::default())?;
    let dataset = Dataset::new(Suite::Cifar, emtopt::data::DATA_SEED);

    println!("serving {model_key} behind the router: {requests} requests from {clients} clients");
    let t0 = std::time::Instant::now();
    let per = (requests as usize).div_ceil(clients);
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let cl = client.clone();
            let ds = dataset.clone();
            std::thread::spawn(move || {
                let mut ok = 0u32;
                let mut correct = 0u32;
                for i in 0..per {
                    let idx = (c * per + i) as u64;
                    let mut img = vec![0.0f32; emtopt::data::IMG_LEN];
                    let label = ds.sample_into(Split::Test, idx, &mut img);
                    match cl.classify(img) {
                        Ok(pred) => {
                            ok += 1;
                            if pred == label as usize {
                                correct += 1;
                            }
                        }
                        Err(_) => {}
                    }
                }
                (ok, correct)
            })
        })
        .collect();
    let (mut ok, mut correct) = (0u32, 0u32);
    for h in handles {
        let (o, c) = h.join().unwrap();
        ok += o;
        correct += c;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{ok} ok / {} sent in {dt:.2}s -> {:.0} req/s",
        per * clients,
        ok as f64 / dt
    );
    println!(
        "accuracy on served traffic: {:.1}% | mean queue {:.2} ms | batch fill {:.0}%",
        100.0 * correct as f64 / ok.max(1) as f64,
        stats.mean_queue_us() / 1000.0,
        stats.mean_batch_fill(16) * 100.0
    );
    drop(client);
    engine.join().ok();
    Ok(())
}
