//! Quickstart: the 60-second tour of the stack — one crossbar MAC, a
//! batched noisy inference over the shared-state execution engine, a spin
//! of the native serving router, and (with `--features aot`) one batch
//! through the AOT artifacts.
//!
//!     cargo run --release --example quickstart
//!     make artifacts && cargo run --release --example quickstart --features aot

use std::sync::Arc;

use emtopt::coordinator::router::{serve_native, NativeServerConfig};
use emtopt::crossbar::{CrossbarArray, ReadCounters};
use emtopt::data::{Dataset, Split, Suite, IMG_LEN};
use emtopt::device::{self, DeviceConfig};
use emtopt::energy::ReadMode;
use emtopt::inference::template_classifier;
use emtopt::rng::Rng;

fn main() -> emtopt::Result<()> {
    // --- native device substrate: one crossbar MAC with RTN sampling ---
    let cfg = DeviceConfig::default();
    let mut rng = Rng::new(3);
    // bulk Box–Muller draw: both halves of every pair land in the buffer
    let mut w = vec![0.0f32; 64 * 16];
    rng.fill_normal(&mut w);
    for v in &mut w {
        *v *= 0.3;
    }
    let arr = CrossbarArray::program(&w, 64, 16, &cfg);
    let xin: Vec<f32> = (0..64).map(|_| rng.next_f32()).collect();
    let mut out = vec![0.0f32; 16];
    let mut counters = ReadCounters::default();
    arr.mac(
        &xin,
        &mut out,
        arr.read_plan(ReadMode::Original),
        cfg.act_bits,
        1.0,
        &mut rng,
        &mut counters,
    );
    println!(
        "crossbar MAC: {} cells, {:.1} pJ analog + {:.1} pJ peripheral",
        arr.num_cells(),
        counters.cell_pj,
        counters.peripheral_pj
    );
    println!(
        "device: sigma_rel(rho=1) = {:.3}, sigma_rel(rho=16) = {:.3}  (amplitude-energy tradeoff)",
        device::sigma_rel(1.0, 1.0),
        device::sigma_rel(16.0, 1.0)
    );

    // --- batched execution engine: immutable model, per-sample RNG streams ---
    let dataset = Dataset::new(Suite::Cifar, emtopt::data::DATA_SEED);
    let model = Arc::new(template_classifier(&dataset, &cfg)?);
    let batch = 32usize;
    let mut xs = vec![0.0f32; batch * IMG_LEN];
    let mut labels = Vec::with_capacity(batch);
    for i in 0..batch {
        labels.push(dataset.sample_into(
            Split::Test,
            i as u64,
            &mut xs[i * IMG_LEN..(i + 1) * IMG_LEN],
        ));
    }
    let mut batch_counters = ReadCounters::default();
    let plan = model.uniform_plan(ReadMode::Original);
    let logits = model.forward_batch(&xs, &plan, &cfg, 1, &mut batch_counters);
    let nc = model.d_out();
    let correct = (0..batch)
        .filter(|&i| {
            let row = &logits[i * nc..(i + 1) * nc];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(c, _)| c)
                .unwrap_or(0);
            pred == labels[i] as usize
        })
        .count();
    println!(
        "batched noisy inference (template classifier, {} rayon threads): \
         {correct}/{batch} correct, {:.1} nJ total",
        rayon::current_num_threads(),
        batch_counters.total_pj() / 1000.0
    );

    // --- native serving engine: the same shared Arc<NoisyModel> behind the router ---
    let (client, stats, engines) = serve_native(model.clone(), NativeServerConfig::default())?;
    let mut served_correct = 0;
    let served = 24u64;
    for i in 0..served {
        let mut img = vec![0.0f32; IMG_LEN];
        let label = dataset.sample_into(Split::Test, 1000 + i, &mut img);
        if client.classify(img)? == label as usize {
            served_correct += 1;
        }
    }
    println!(
        "router: {served_correct}/{served} correct, mean queue {:.2} ms, {:.1} nJ/request",
        stats.mean_queue_us() / 1000.0,
        stats.mean_energy_pj_per_request() / 1000.0
    );
    drop(client);
    for h in engines {
        h.join().ok();
    }

    // --- AOT runtime: load a jax/pallas-lowered model through PJRT ---
    #[cfg(feature = "aot")]
    {
        use emtopt::runtime::{execute, scalar_i32, to_vec_f32, Artifacts, Predictor};
        let arts = Artifacts::open_default()?;
        println!("PJRT platform: {}", arts.runtime.platform());
        let init = arts.manifest.artifact("mlp_10_init")?;
        let init_exe = arts.runtime.load_hlo(&arts.dir.join(&init.file))?;
        let mut outs = execute(&init_exe, &[scalar_i32(42)])?;
        let rho_raw = to_vec_f32(&outs.pop().unwrap())?;
        let params = outs;
        println!(
            "initialised mlp_10: {} parameter tensors, {} crossbar layers",
            params.len(),
            rho_raw.len()
        );
        let predictor = Predictor::new(&arts, "mlp_10")?;
        let (ax, ay) = dataset.batch(Split::Test, 0, predictor.batch);
        let alogits = predictor.predict(&params, &rho_raw, &ax, 1, 1.0)?;
        let anc = predictor.num_classes;
        let acorrect = (0..predictor.batch)
            .filter(|&i| {
                let row = &alogits[i * anc..(i + 1) * anc];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .unwrap()
                    .0;
                pred == ay[i] as usize
            })
            .count();
        println!(
            "noisy AOT inference on untrained model: {acorrect}/{} correct (chance ~10%)",
            predictor.batch
        );
    }
    #[cfg(not(feature = "aot"))]
    println!("(AOT/PJRT tour skipped: rebuild with --features aot and `make artifacts`)");

    Ok(())
}
