//! Quickstart: load the AOT artifacts, run one noisy inference batch, and
//! inspect the native device simulator — the 60-second tour of the stack.
//!
//!     make artifacts && cargo run --release --example quickstart

use emtopt::crossbar::CrossbarArray;
use emtopt::data::{Dataset, Split, Suite};
use emtopt::device::{self, DeviceConfig};
use emtopt::energy::ReadMode;
use emtopt::rng::Rng;
use emtopt::runtime::{execute, scalar_i32, to_vec_f32, Artifacts, Predictor};

fn main() -> emtopt::Result<()> {
    // --- Layer 3 runtime: load a jax/pallas-lowered model through PJRT ---
    let arts = Artifacts::open_default()?;
    println!("PJRT platform: {}", arts.runtime.platform());

    // He-init parameters through the model's init artifact
    let init = arts.manifest.artifact("mlp_10_init")?;
    let init_exe = arts.runtime.load_hlo(&arts.dir.join(&init.file))?;
    let mut outs = execute(&init_exe, &[scalar_i32(42)])?;
    let rho_raw = to_vec_f32(&outs.pop().unwrap())?;
    let params = outs;
    println!(
        "initialised mlp_10: {} parameter tensors, {} crossbar layers",
        params.len(),
        rho_raw.len()
    );

    // one noisy inference batch (the EMT fluctuation is sampled INSIDE the
    // lowered computation — eq. 11 of the paper, pallas kernel on the FC)
    let predictor = Predictor::new(&arts, "mlp_10")?;
    let dataset = Dataset::new(Suite::Cifar, emtopt::data::DATA_SEED);
    let (x, y) = dataset.batch(Split::Test, 0, predictor.batch);
    let logits = predictor.predict(&params, &rho_raw, &x, 1, 1.0)?;
    let nc = predictor.num_classes;
    let correct = (0..predictor.batch)
        .filter(|&i| {
            let row = &logits[i * nc..(i + 1) * nc];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            pred == y[i] as usize
        })
        .count();
    println!(
        "noisy inference on untrained model: {correct}/{} correct (chance ~10%)",
        predictor.batch
    );

    // --- native device substrate: one crossbar MAC with RTN sampling ---
    let cfg = DeviceConfig::default();
    let mut rng = Rng::new(3);
    let w: Vec<f32> = (0..64 * 16).map(|_| rng.normal() * 0.3).collect();
    let mut arr = CrossbarArray::program(&w, 64, 16, &cfg);
    let xin: Vec<f32> = (0..64).map(|_| rng.next_f32()).collect();
    let mut out = vec![0.0f32; 16];
    arr.mac(&xin, &mut out, ReadMode::Original, cfg.act_bits, 1.0, &mut rng);
    println!(
        "crossbar MAC: {} cells, {:.1} pJ analog + {:.1} pJ peripheral",
        arr.num_cells(),
        arr.counters.cell_pj,
        arr.counters.peripheral_pj
    );
    println!(
        "device: sigma_rel(rho=1) = {:.3}, sigma_rel(rho=16) = {:.3}  (eq. amplitude-energy tradeoff)",
        device::sigma_rel(1.0, 1.0),
        device::sigma_rel(16.0, 1.0)
    );
    Ok(())
}
