//! Ablation sweep (Fig 9 companion): accuracy-vs-energy curves of all
//! four solutions on one model, printed as aligned series — the data
//! behind `cargo bench --bench fig9` for a single model.
//!
//!     cargo run --release --example ablation_sweep -- --model mlp_10

use emtopt::coordinator::{self, store, Solution};
use emtopt::data::Suite;
use emtopt::energy::EnergyModel;
use emtopt::metrics::{fmt_energy_uj, fmt_pct, Table};
use emtopt::runtime::{Artifacts, Evaluator};
use emtopt::util::cli::Args;

fn main() -> emtopt::Result<()> {
    let args = Args::parse()?;
    let model_key = args.str_or("model", "mlp_10");
    let suite = if model_key.ends_with("_20") {
        Suite::ImageNet
    } else {
        Suite::Cifar
    };
    let arts = Artifacts::open_default()?;
    let cfg = coordinator::experiments::schedule_for(&model_key);
    let em = EnergyModel::new(arts.manifest.device.act_bits);
    let paper = coordinator::experiments::paper_model_for(&model_key)
        .ok_or_else(|| anyhow::anyhow!("no paper mapping for {model_key}"))?;
    let setup = coordinator::EvalSetup {
        suite,
        batches: 1,
        ..Default::default()
    };
    let grid = coordinator::experiments::default_rho_grid();

    let mut table = Table::new(
        format!("{model_key} ablation: accuracy vs energy ({})", paper.name),
        &["solution", "rho-scale", "energy (uJ)", "top-1"],
    );
    for sol in Solution::ALL {
        let trained = store::train_cached(&arts, &model_key, suite, sol, &cfg)?;
        let evaluator = Evaluator::new(&arts, &model_key, sol.decomposed())?;
        let pts = coordinator::sweep_accuracy_vs_energy(
            &evaluator,
            &trained,
            &setup,
            &paper,
            sol.method(),
            &em,
            &grid,
        )?;
        for p in pts {
            table.row(vec![
                sol.name().into(),
                format!("{:.3}", p.rho_scale),
                fmt_energy_uj(p.energy_uj),
                fmt_pct(p.top1),
            ]);
        }
    }
    table.print();
    Ok(())
}
