//! Device explorer: visualise the RTN cell model — state dwell
//! trajectories, the amplitude-vs-rho law (Fig 2b of the paper), and the
//! fluctuation-averaging effect of the low-fluctuation decomposition at
//! the single-array level (eq. 16-18).
//!
//!     cargo run --release --example device_explorer

use emtopt::crossbar::{CrossbarArray, ReadCounters};
use emtopt::device::{self, DeviceConfig, Intensity, RtnCell};
use emtopt::energy::ReadMode;
use emtopt::rng::Rng;

fn main() -> emtopt::Result<()> {
    let mut rng = Rng::new(2024);

    println!("=== RTN state trajectory (4-state cell, dwell = 8 cycles) ===");
    let mut cell = RtnCell::new(4, 8.0);
    let glyphs = ['_', '-', '=', '#'];
    let mut line = String::new();
    for _ in 0..64 {
        cell.advance(1, &mut rng);
        line.push(glyphs[cell.state().0]);
    }
    println!("{line}");

    println!("\n=== fluctuation amplitude vs energy coefficient (Fig 2) ===");
    println!("{:>8} {:>12} {:>14}", "rho", "sigma_rel", "E/read (norm)");
    for rho in [0.25f32, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0] {
        println!(
            "{rho:>8.2} {:>12.4} {:>14.3}",
            device::sigma_rel(rho, 1.0),
            device::read_energy(rho, 0.25, 8.0)
        );
    }

    println!("\n=== intensity levels (paper §5.2) ===");
    for i in Intensity::ALL {
        println!(
            "  {:<7} sigma_rel(rho=1) = {:.4}",
            i.name(),
            device::sigma_rel(1.0, i.factor())
        );
    }

    println!("\n=== decomposition fluctuation averaging (eq. 16-18) ===");
    let (k, n) = (128usize, 8usize);
    // bulk Box–Muller draw: both halves of every pair land in the buffer
    let mut w = vec![0.0f32; k * n];
    rng.fill_normal(&mut w);
    for v in &mut w {
        *v *= 0.3;
    }
    let x: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
    println!(
        "{:>8} {:>16} {:>16} {:>8}",
        "rho", "std(original)", "std(decomposed)", "ratio"
    );
    for rho in [0.25f32, 1.0, 4.0] {
        let std_of = |mode: ReadMode, rng: &mut Rng| {
            let cfg = DeviceConfig {
                rho,
                ..DeviceConfig::default()
            };
            let arr = CrossbarArray::program(&w, k, n, &cfg);
            let trials = 300;
            let mut counters = ReadCounters::default();
            let mut out = vec![0.0f32; n];
            let mut sum = vec![0.0f64; n];
            let mut sq = vec![0.0f64; n];
            for _ in 0..trials {
                arr.mac(&x, &mut out, arr.read_plan(mode), 5, 1.0, rng, &mut counters);
                for c in 0..n {
                    sum[c] += out[c] as f64;
                    sq[c] += (out[c] as f64).powi(2);
                }
            }
            (0..n)
                .map(|c| {
                    let m = sum[c] / trials as f64;
                    (sq[c] / trials as f64 - m * m).max(0.0).sqrt()
                })
                .sum::<f64>()
                / n as f64
        };
        let so = std_of(ReadMode::Original, &mut rng);
        let sd = std_of(ReadMode::Decomposed, &mut rng);
        println!("{rho:>8.2} {so:>16.5} {sd:>16.5} {:>8.2}x", so / sd);
    }
    println!("(paper: sqrt-law reduction -> ratio > 1 at every rho)");
    Ok(())
}
