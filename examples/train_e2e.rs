//! End-to-end driver (the repo's headline validation): pretrain a model,
//! fine-tune it with each solution (trad / A / A+B / A+B+C), log the loss
//! curves, then evaluate accuracy + paper-scale energy on the simulated
//! EMT device — proving all three layers compose:
//!   rust coordinator -> PJRT -> XLA -> (jax model -> pallas kernels).
//!
//!     cargo run --release --example train_e2e -- --model mlp_10
//!
//! Results are recorded in EXPERIMENTS.md.

use emtopt::coordinator::{self, store, Solution};
use emtopt::data::Suite;
use emtopt::energy::EnergyModel;
use emtopt::metrics::{fmt_energy_uj, fmt_pct, Table};
use emtopt::runtime::{Artifacts, Evaluator};
use emtopt::util::cli::Args;

fn main() -> emtopt::Result<()> {
    let args = Args::parse()?;
    let model_key = args.str_or("model", "mlp_10");
    let suite = if model_key.ends_with("_20") {
        Suite::ImageNet
    } else {
        Suite::Cifar
    };
    let arts = Artifacts::open_default()?;
    let mut cfg = coordinator::experiments::schedule_for(&model_key);
    cfg.pretrain_steps = args.parse_or("pretrain", cfg.pretrain_steps)?;
    cfg.finetune_steps = args.parse_or("finetune", cfg.finetune_steps)?;
    cfg.log_every = 20;

    println!(
        "=== end-to-end: {model_key} on {} ({} pretrain + {} finetune steps/solution) ===",
        suite.name(),
        cfg.pretrain_steps,
        cfg.finetune_steps
    );

    let em = EnergyModel::new(arts.manifest.device.act_bits);
    let paper = coordinator::experiments::paper_model_for(&model_key)
        .ok_or_else(|| anyhow::anyhow!("no paper mapping for {model_key}"))?;
    let setup = coordinator::EvalSetup {
        suite,
        batches: 1,
        ..Default::default()
    };

    let mut table = Table::new(
        format!("{model_key}: solution ladder (noisy top-1 at trained rho)"),
        &["solution", "final loss", "noisy top-1", "mean rho", "energy (uJ)"],
    );
    for sol in Solution::ALL {
        let t0 = std::time::Instant::now();
        let trained = store::train_cached(&arts, &model_key, suite, sol, &cfg)?;
        // loss curve (first/last few points)
        let lt = &trained.loss_trace;
        if !lt.is_empty() {
            let head: Vec<String> = lt.iter().take(3).map(|l| format!("{l:.3}")).collect();
            let tail: Vec<String> =
                lt.iter().rev().take(3).rev().map(|l| format!("{l:.3}")).collect();
            println!(
                "[{}] loss curve: {} ... {}  ({} steps, {:.0}s)",
                sol.name(),
                head.join(" "),
                tail.join(" "),
                lt.len(),
                t0.elapsed().as_secs_f64()
            );
        }
        let evaluator = Evaluator::new(&arts, &model_key, sol.decomposed())?;
        let r = coordinator::experiments::eval_at_scale(
            &evaluator, &trained, &setup, 1.0, 1.0, 1.0,
        )?;
        let mean_rho = trained.mean_rho(1.0);
        let energy = em.model_uj_uniform(&paper, mean_rho, sol.read_mode());
        table.row(vec![
            sol.name().into(),
            trained
                .loss_trace
                .last()
                .map(|l| format!("{l:.3}"))
                .unwrap_or_else(|| "-".into()),
            fmt_pct(r.top1_acc()),
            format!("{mean_rho:.2}"),
            fmt_energy_uj(energy),
        ]);
    }
    table.print();
    println!("expected shape: trad < A < A+B <= A+B+C top-1; A+B/A+B+C lower energy");
    Ok(())
}
